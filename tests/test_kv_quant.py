"""Deterministic goldens for the quantized KV-cache subsystem
(repro.quant) plus the operation-sequence checker the hypothesis
harness in test_quant_properties.py randomises.

Covers, bottom-up:

* the policy registry (none / int8 / fp8) and ``ServeConfig.kv_quant``
  validation;
* ``check_quant_roundtrip`` — the single-pass error-bound law per
  policy, including the fp8 clip-before-cast edge (|x| > 448 must not
  produce nan codes);
* ``quant_write_kv`` — block-fill scale reset, scale growth rescaling
  resident codes, the no-growth rewrite bit-identity, and the
  ``block_size * error_bound`` pool-residency bound;
* kernel vs reference — the fused-dequant Pallas decode kernel in
  ``interpret=True`` mode against the pure-jnp reference, int8 and fp8;
* cache variants — pool/scale shapes, ``block_bytes`` accounting, the
  published-block write guard covering scale rows, COW scale copies;
* engine level — the ``kv_quant="none"`` bitwise identity matrix
  (dense and dropless-hash MoE x prefix on/off x mesh 1x1), int8
  end-to-end under ``check_invariants=True``, swap-restore and
  warm-prefix byte preservation, deadline-aware shedding, and the
  ``kv_pool_bytes`` occupancy metric.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ServeConfig, SLOConfig
from repro.quant import (
    available_kv_quants,
    check_quant_roundtrip,
    get_kv_quant,
    quant_write_kv,
)
from repro.quant.kv_cache import (
    QuantizedPagedKVCache,
    QuantizedPrefixCachingKVCache,
)


def _cfg(**kw):
    base = dict(name="t", family="decoder_lm", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
                max_seq_len=128, dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


def _params(cfg, seed=0):
    from repro.models.registry import get_family
    from repro.nn import init

    return init(get_family(cfg).specs(cfg), jax.random.PRNGKey(seed))


# ---------------------------------------------------------------------------
# Policy registry
# ---------------------------------------------------------------------------

def test_registry_and_config_validation():
    names = available_kv_quants()
    assert "none" in names and "int8" in names and "fp8" in names
    assert not get_kv_quant("none").quantized
    assert get_kv_quant("int8").quantized
    assert get_kv_quant("int8") is get_kv_quant("int8")   # singleton: jit-static
    with pytest.raises(ValueError):
        get_kv_quant("int4")
    with pytest.raises(ValueError):
        ServeConfig(max_slots=2, kv_block_size=4, max_len=16, num_blocks=8,
                    kv_quant="bf8")


def test_roundtrip_bounds_golden():
    x = np.array([0.0, 1.0, -1.0, 0.3, 127.0, -63.5, 1e-8], np.float32)
    for name in ("int8", "fp8"):
        deq, scale, max_err = check_quant_roundtrip(x, get_kv_quant(name))
        assert max_err <= float(get_kv_quant(name).error_bound(scale))


def test_fp8_large_values_do_not_nan():
    """e4m3 saturates at 448; casting beyond gives nan — the encoder
    must clip first, so huge inputs produce finite codes."""
    policy = get_kv_quant("fp8")
    x = jnp.asarray([1e4, -1e4, 500.0, 448.0], jnp.float32)
    scale = jnp.abs(x).max() / policy.qmax
    deq = policy.decode(policy.encode(x / jnp.maximum(scale, 1e-30))) * scale
    assert bool(jnp.isfinite(deq).all())


# ---------------------------------------------------------------------------
# quant_write_kv (checker randomised by test_quant_properties.py)
# ---------------------------------------------------------------------------

def check_quant_write_sequence(bs, hkv, hd, name, writes):
    """writes: list of (block, offset, values) partial-row writes into a
    tiny pool.  A host model keeps every row's exact f32 value; after
    every write, each resident row must decode to within
    ``bs * error_bound(scale)`` of its model value (the scale-growth
    compounding law: one extra bound per growth, at most bs - 1 growths
    in a block's lifetime), and scales never shrink except at a
    block-fill (offset 0), which starts a new block lifetime."""
    policy = get_kv_quant(name)
    P = 4
    codes = jnp.zeros((P, hkv, bs, hd), policy.pool_dtype)
    scales = jnp.zeros((P, hkv), jnp.float32)
    model = {}                     # (block, offset) -> (hkv, hd) f32 row
    for blk, off, vals in writes:
        blk, off = blk % P, off % bs
        x = np.asarray(vals, np.float32).reshape(1, hkv, hd)
        before = np.asarray(scales)
        codes, scales = quant_write_kv(
            codes, scales, jnp.asarray(x),
            jnp.asarray([blk], jnp.int32), jnp.asarray([off], jnp.int32),
            policy=policy)
        if off == 0:               # block-fill: prior rows are dead
            model = {k: v for k, v in model.items() if k[0] != blk}
        model[(blk, off)] = x[0]
        after = np.asarray(scales)
        if off != 0:
            assert (after >= before - 1e-30).all()
        deq = np.asarray(policy.decode(codes)) * after[:, :, None, None]
        for (b, o), row in model.items():
            bound = bs * np.asarray(policy.error_bound(jnp.asarray(after[b])))
            err = np.abs(deq[b, :, o] - row)
            assert (err <= bound[:, None] + 1e-6).all(), (b, o, err.max())
    return codes, scales


def test_quant_write_fixed_grid():
    for name in ("int8", "fp8"):
        check_quant_write_sequence(4, 2, 2, name, [
            (0, 0, [1.0, -2.0, 3.0, -4.0]),
            (0, 1, [100.0, 0.5, -0.25, 7.0]),   # scale growth -> rescale
            (0, 2, [0.1, 0.2, 0.3, 0.4]),       # no growth
            (1, 0, [0.0, 0.0, 0.0, 0.0]),       # all-zero block
            (0, 0, [5.0, 5.0, 5.0, 5.0])])      # block refill resets scale


def test_no_growth_rewrite_is_bit_identity():
    """Rewriting with values inside the block's current absmax does not
    touch any other row's codes: decode -> divide by the same scale ->
    re-encode reproduces them exactly."""
    policy = get_kv_quant("int8")
    codes = jnp.zeros((2, 1, 4, 2), policy.pool_dtype)
    scales = jnp.zeros((2, 1), jnp.float32)
    big = np.full((1, 1, 2), 8.0, np.float32)
    codes, scales = quant_write_kv(
        codes, scales, jnp.asarray(big), jnp.asarray([0], jnp.int32),
        jnp.asarray([0], jnp.int32), policy=policy)
    snap = np.asarray(codes[0, :, 0])
    small = np.full((1, 1, 2), 1.5, np.float32)       # within absmax 8
    codes2, scales2 = quant_write_kv(
        codes, scales, jnp.asarray(small), jnp.asarray([0], jnp.int32),
        jnp.asarray([1], jnp.int32), policy=policy)
    assert np.array_equal(np.asarray(scales2), np.asarray(scales))
    assert np.array_equal(np.asarray(codes2[0, :, 0]), snap)


# ---------------------------------------------------------------------------
# Fused-dequant kernel vs pure-jnp reference (interpret mode)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["int8", "fp8"])
def test_quantized_kernel_matches_ref(name):
    from repro.kernels.decode_attention.kernel import (
        quantized_paged_decode_attention_kernel,
    )
    from repro.kernels.decode_attention.ref import (
        quantized_paged_decode_attention_ref,
    )

    policy = get_kv_quant(name)
    key = jax.random.PRNGKey(0)
    N, H, G, D, P, bs, n_b = 3, 2, 2, 8, 9, 4, 2
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (N, H, G, D), jnp.float32)
    kf = jax.random.normal(ks[1], (P, H, bs, D), jnp.float32)
    vf = jax.random.normal(ks[2], (P, H, bs, D), jnp.float32)

    def enc(x):                    # per-(block, head) absmax quantization
        s = jnp.abs(x).max(axis=(2, 3)) / policy.qmax
        codes = policy.encode(x / jnp.maximum(s, 1e-30)[:, :, None, None])
        return jnp.moveaxis(codes, 1, 1).astype(policy.pool_dtype), s

    k_pool, k_scales = enc(kf)
    v_pool, v_scales = enc(vf)
    # pool layout is (P, H, bs, D) / scales (P, H)
    tbl = jnp.asarray([[1, 2], [3, 4], [5, 0]], jnp.int32)
    lens = jnp.asarray([5, 8, 3], jnp.int32)
    # ref takes flat (N, Hq, D) queries, the kernel grouped (N, Hkv, G, D)
    ref = quantized_paged_decode_attention_ref(
        q.reshape(N, H * G, D), k_pool, v_pool, k_scales, v_scales, tbl,
        lens, policy=policy)
    out = quantized_paged_decode_attention_kernel(
        q, k_pool, v_pool, k_scales, v_scales, tbl, lens,
        decode=policy.decode, interpret=True)
    np.testing.assert_allclose(np.asarray(out).reshape(N, H * G, D),
                               np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_quantized_update_attention_end_to_end():
    from repro.kernels.decode_attention import quantized_paged_update_attention

    policy = get_kv_quant("int8")
    N, H, G, D, P, bs, n_b = 2, 2, 1, 8, 5, 4, 2
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (N, H * G, D), jnp.float32)
    k_new = jax.random.normal(ks[1], (N, H, D), jnp.float32)
    v_new = jax.random.normal(ks[2], (N, H, D), jnp.float32)
    k_pool = jnp.zeros((P, H, bs, D), policy.pool_dtype)
    v_pool = jnp.zeros_like(k_pool)
    k_sc = jnp.zeros((P, H), jnp.float32)
    v_sc = jnp.zeros_like(k_sc)
    tbl = jnp.asarray([[1, 2], [3, 4]], jnp.int32)
    lens = jnp.asarray([1, 1], jnp.int32)
    wb = jnp.asarray([1, 3], jnp.int32)
    wo = jnp.asarray([0, 0], jnp.int32)
    out, k_pool, v_pool, k_sc, v_sc = quantized_paged_update_attention(
        q, k_new, v_new, k_pool, v_pool, k_sc, v_sc, wb, wo, tbl, lens,
        policy=policy)
    assert out.shape == (N, H * G, D)
    assert bool(jnp.isfinite(out).all())
    # written blocks got scales; untouched blocks stayed zero
    assert float(k_sc[1].min()) > 0 and float(k_sc[3].min()) > 0
    assert float(k_sc[0].max()) == 0 and float(k_sc[2].max()) == 0


# ---------------------------------------------------------------------------
# Cache variants: shapes, byte accounting, write guards, COW
# ---------------------------------------------------------------------------

def _qserve(prefix=False, num_blocks=16, **kw):
    return ServeConfig(max_slots=4, kv_block_size=4, max_len=64,
                       num_blocks=num_blocks, prefix_cache=prefix,
                       kv_quant=kw.pop("kv_quant", "int8"), **kw)


def test_quantized_cache_pools_and_block_bytes():
    from repro.serving.kv_cache import PagedKVCache

    cfg = _cfg()
    cache = QuantizedPagedKVCache(cfg, _qserve())
    assert cache.k_pool.dtype == jnp.int8
    assert cache.k_scales.shape == (2, 17, 2)       # (L, blocks+1, Hkv)
    base = PagedKVCache(cfg, ServeConfig(max_slots=4, kv_block_size=4,
                                         max_len=64, num_blocks=16))
    # int8 + f32 scales vs f32 codes: quarter the bytes, plus epsilon
    assert cache.block_bytes < 0.30 * base.block_bytes
    assert cache.occupancy()[0]["block_bytes"] == cache.block_bytes
    cache.check_conservation()


def test_published_block_scale_double_write_raises():
    """A published block is immutable codes + an immutable scale: the
    write guard rejects any coordinate into it, so its scale row can
    never be rewritten while the block is matchable."""
    cfg = _cfg(num_layers=1)
    cache = QuantizedPrefixCachingKVCache(cfg, _qserve(prefix=True))
    prompt = np.arange(9, dtype=np.int32)
    cache.allocate_slot(0, 12, prompt=prompt)
    cache.ensure_capacity(0, 9)
    cache.commit(0, prompt)                    # publishes blocks 0..1
    held = cache._slot_blocks[0]
    assert cache.index.published(held[0])
    with pytest.raises(RuntimeError):
        cache.write_coords(0, 2)               # inside a published block
    # a fresh binder must not be able to write the shared blocks either
    cache.allocate_slot(1, 12, prompt=prompt)
    with pytest.raises(RuntimeError):
        cache.write_coords(1, 0)
    cache.check_conservation()


def test_cow_detach_copies_scale_rows():
    cfg = _cfg(num_layers=1)
    cache = QuantizedPrefixCachingKVCache(cfg, _qserve(prefix=True))
    prompt = np.arange(12, dtype=np.int32)
    cache.allocate_slot(0, 16, prompt=prompt)
    cache.ensure_capacity(0, 12)
    cache.commit(0, prompt)                    # publishes blocks 0..2
    held0 = list(cache._slot_blocks[0])
    # stamp recognisable scales on the block the COW edge will hit
    cache.k_scales = cache.k_scales.at[:, held0[1]].set(7.0)
    cache.v_scales = cache.v_scales.at[:, held0[1]].set(3.0)
    cache.allocate_slot(1, 16, prompt=prompt)  # binds blocks 0..1 (8 cached)
    assert cache._slot_bound[1] == 2
    cache.truncate_slot(0, 5)                  # COW: slot 0 detaches block 1
    new1 = cache._slot_blocks[0][1]
    assert new1 != held0[1]
    assert (np.asarray(cache.k_scales[:, new1]) == 7.0).all()
    assert (np.asarray(cache.v_scales[:, new1]) == 3.0).all()
    cache.check_conservation()


# ---------------------------------------------------------------------------
# Engine level: the quant=none identity matrix, int8 e2e, swap, prefix
# ---------------------------------------------------------------------------

TRIVIAL_MESH = (("data", 1), ("expert", 1))


def _requests(gen=6, vocab=128):
    from repro.serving.request import Request

    rng = np.random.default_rng(0)
    return [Request(uid=i,
                    prompt=rng.integers(1, vocab, int(l)).astype(np.int32),
                    max_new_tokens=gen)
            for i, l in enumerate([5, 9, 13, 7])]


def _trace(cfg, params, *, kv_quant="none", prefix=False, mesh=None,
           slo=None, check=True, num_blocks=48, requests=None, obs=None):
    from repro.serving.continuous import ContinuousEngine

    serve = ServeConfig(max_slots=3, kv_block_size=4, prefill_chunk=4,
                        max_len=64, num_blocks=num_blocks,
                        prefix_cache=prefix, kv_quant=kv_quant, slo=slo,
                        mesh=mesh)
    eng = ContinuousEngine(cfg, params, serve, check_invariants=check,
                           obs=obs)
    toks, stats = eng.run(requests if requests is not None else _requests())
    return toks, stats, eng


def test_none_identity_matrix_dense():
    """kv_quant='none' is bitwise token-identical to the pre-quant
    engine path across prefix on/off and the 1x1 mesh."""
    cfg = _cfg()
    params = _params(cfg)
    base, _, _ = _trace(cfg, params, check=False)
    for prefix in (False, True):
        toks, _, _ = _trace(cfg, params, prefix=prefix)
        assert toks == base
    mesh_toks, _, _ = _trace(cfg, params, mesh=TRIVIAL_MESH)
    assert mesh_toks == base


def test_none_identity_dropless_hash():
    cfg = _cfg().replace_moe(impl="dropless", num_experts=4,
                             routing="hash", capacity_factor=None)
    params = _params(cfg)
    base, _, _ = _trace(cfg, params, check=False)
    warm, _, _ = _trace(cfg, params, prefix=True)
    assert warm == base
    mesh_toks, _, _ = _trace(cfg, params, mesh=TRIVIAL_MESH)
    assert mesh_toks == base


def test_int8_end_to_end_with_invariants():
    cfg = _cfg()
    params = _params(cfg)
    toks, _, eng = _trace(cfg, params, kv_quant="int8")
    assert all(len(t) == 6 for t in toks.values())
    assert eng.cache.k_pool.dtype == jnp.int8
    eng.cache.check_conservation()
    # deterministic: same trace, same tokens
    toks2, _, _ = _trace(cfg, params, kv_quant="int8")
    assert toks == toks2


def test_int8_mesh_matches_single_device():
    cfg = _cfg()
    params = _params(cfg)
    single, _, _ = _trace(cfg, params, kv_quant="int8")
    mesh, _, eng = _trace(cfg, params, kv_quant="int8", mesh=TRIVIAL_MESH)
    assert mesh == single
    from repro.serving.kv_cache import ShardedPagedKVCache

    assert isinstance(eng.cache, ShardedPagedKVCache)
    assert eng.cache.k_scales is not None


def test_int8_warm_prefix_preserves_published_bytes():
    """Warm reuse serves the published blocks' quantized bytes exactly:
    the warm run is token-identical to cold and actually binds blocks."""
    cfg = _cfg()
    params = _params(cfg)
    reqs = _requests()
    # two tenants sharing a prompt so the warm run has something to bind
    for r in reqs[1:]:
        r.prompt[:4] = reqs[0].prompt[:4]
    cold, _, _ = _trace(cfg, params, kv_quant="int8", requests=reqs)
    warm, s, eng = _trace(cfg, params, kv_quant="int8", prefix=True,
                          requests=reqs)
    assert cold == warm
    assert s["cached_tokens"] > 0
    eng.cache.check_conservation()


def test_int8_swap_restore_token_identical():
    """Preempt + restore under int8: host pools hold codes + scales
    verbatim, so the resumed request is token-identical to an
    un-preempted run (no re-quantization in flight)."""
    from repro.serving.request import Priority, Request

    cfg = _cfg()
    params = _params(cfg)
    rng = np.random.default_rng(1)
    low = Request(uid=0, prompt=rng.integers(1, 128, 12).astype(np.int32),
                  max_new_tokens=10, arrival_ms=0.0, priority=Priority.LOW)
    high = Request(uid=1, prompt=rng.integers(1, 128, 8).astype(np.int32),
                   max_new_tokens=4, arrival_ms=1.0, priority=Priority.HIGH)

    def run(reqs, slo):
        from repro.serving.continuous import ContinuousEngine

        serve = ServeConfig(max_slots=1, kv_block_size=4, prefill_chunk=4,
                            max_len=64, num_blocks=32, kv_quant="int8",
                            slo=slo)
        eng = ContinuousEngine(cfg, params, serve, check_invariants=True)
        return eng.run(reqs)

    toks, stats = run([low, high], SLOConfig(preemption=True))
    assert stats["preemptions"] >= 1
    solo, _ = run([Request(uid=0, prompt=low.prompt, max_new_tokens=10)],
                  None)
    assert toks[0] == solo[0]


def test_quantized_swap_manager_preserves_bytes():
    """Direct store/load round trip: codes and scale rows come back to
    the device bit-identical."""
    from repro.serving.slo.swap import SwapManager

    cfg = _cfg(num_layers=1)
    cache = QuantizedPagedKVCache(cfg, _qserve(num_blocks=8))
    cache.allocate_slot(0, 8)
    cache.ensure_capacity(0, 8)
    blocks = list(cache._slot_blocks[0])
    key = jax.random.PRNGKey(0)
    cache.k_pool = jax.random.randint(key, cache.k_pool.shape, -127, 128,
                                      jnp.int8)
    cache.v_pool = jax.random.randint(key, cache.v_pool.shape, -127, 128,
                                      jnp.int8)
    cache.k_scales = jax.random.uniform(key, cache.k_scales.shape)
    cache.v_scales = jax.random.uniform(key, cache.v_scales.shape) + 1.0
    k_snap = np.asarray(cache.k_pool[:, blocks]).copy()
    ks_snap = np.asarray(cache.k_scales[:, blocks]).copy()
    vs_snap = np.asarray(cache.v_scales[:, blocks]).copy()
    swap = SwapManager(cache, host_blocks=8)
    rec = cache.swap_out(0, swap, uid=0, total_len=8, context_len=8)
    # (swap_out released the slot)  clobber the device rows, then
    # restore into a fresh slot
    cache.k_pool = jnp.zeros_like(cache.k_pool)
    cache.k_scales = jnp.zeros_like(cache.k_scales)
    cache.v_scales = jnp.zeros_like(cache.v_scales)
    resume = cache.restore_slot(1, rec, swap)
    swap.release(rec)
    assert resume == 8
    new_blocks = list(cache._slot_blocks[1])
    assert np.array_equal(np.asarray(cache.k_pool[:, new_blocks]), k_snap)
    assert np.array_equal(np.asarray(cache.k_scales[:, new_blocks]), ks_snap)
    assert np.array_equal(np.asarray(cache.v_scales[:, new_blocks]), vs_snap)
    swap.check_conservation()
    cache.check_conservation()


# ---------------------------------------------------------------------------
# Deadline-aware shedding (PR 7 follow-on)
# ---------------------------------------------------------------------------

def _shed_requests(vocab=128):
    from repro.serving.request import Request

    rng = np.random.default_rng(0)
    mk = lambda uid, gen, **kw: Request(
        uid=uid, prompt=rng.integers(1, vocab, 8).astype(np.int32),
        max_new_tokens=gen, **kw)
    return [mk(0, 8, arrival_ms=0.0),                  # establishes the EMA
            mk(1, 8, arrival_ms=1.0, deadline_ms=1.5),  # provably unmeetable
            mk(2, 4, arrival_ms=1.0)]                   # deadline-free


def _shed_trace(cfg, params, slo):
    """One slot serialises the queue: request 0 finishes (measuring the
    decode rate) while 1 and 2 wait — only then can shedding judge 1's
    deadline against evidence."""
    from repro.serving.continuous import ContinuousEngine

    serve = ServeConfig(max_slots=1, kv_block_size=4, prefill_chunk=4,
                        max_len=64, num_blocks=32, slo=slo)
    eng = ContinuousEngine(cfg, params, serve, check_invariants=True)
    toks, stats = eng.run(_shed_requests())
    return toks, stats, eng


def test_shed_provably_unmeetable():
    cfg = _cfg()
    params = _params(cfg)
    toks, stats, eng = _shed_trace(cfg, params,
                                   SLOConfig(preemption=False, shed=True))
    assert stats["requests_shed"] == 1
    assert toks[1] == []                     # shed: no tokens at all
    assert len(toks[0]) == 8 and len(toks[2]) == 4
    assert eng.obs.metrics.get("requests_shed_total") == 1


def test_shed_off_by_default():
    cfg = _cfg()
    params = _params(cfg)
    toks, stats, _ = _shed_trace(cfg, params, SLOConfig(preemption=False))
    assert "requests_shed" not in stats
    assert len(toks[1]) > 0                  # served (late), never rejected


def test_shed_needs_measured_rate():
    """Nothing is shed before the first finish establishes ms/token —
    a request whose deadline passed before any measurement exists is
    still served."""
    from repro.serving.scheduler import Scheduler

    sched = Scheduler(2, 64, None, slo=SLOConfig(preemption=False, shed=True))
    assert sched._decode_ms_ema is None
    assert sched.shed_unmeetable(1e9) == []


# ---------------------------------------------------------------------------
# Observability: kv_pool_bytes + the 1x1-mesh trace (PR 9 follow-on)
# ---------------------------------------------------------------------------

def test_kv_pool_bytes_metric_shrinks_under_int8(tmp_path):
    cfg = _cfg()
    params = _params(cfg)

    def pool_bytes(kv_quant):
        _, _, eng = _trace(cfg, params, kv_quant=kv_quant)
        return eng.obs.metrics.get("kv_pool_bytes", shard=0)

    none_b, int8_b = pool_bytes("none"), pool_bytes("int8")
    assert none_b > 0 and int8_b > 0
    assert int8_b <= 0.55 * none_b


def test_mesh_trace_validates_with_require(tmp_path):
    """A 1x1-mesh serve run emits per-shard engine_step_shard spans
    inside each engine_step span; the written Chrome trace validates,
    and the metrics file validates with --require for the new gauge."""
    from repro.obs import Observability
    from repro.obs.validate import (
        validate_chrome_trace,
        validate_metrics_jsonl,
    )

    cfg = _cfg()
    params = _params(cfg)
    obs = Observability(tracing=True)
    _, _, eng = _trace(cfg, params, kv_quant="int8", mesh=TRIVIAL_MESH,
                       obs=obs)
    spans = [e for e in obs.tracer.events()
             if e.get("name") == "engine_step_shard"]
    assert spans, "mesh path emitted no per-shard spans"
    assert all(e["args"]["shard"] == 0 for e in spans)
    assert all("live_rows" in e["args"] for e in spans)
    trace_path = str(tmp_path / "trace.json")
    metrics_path = str(tmp_path / "metrics.jsonl")
    obs.tracer.write_chrome_trace(trace_path)
    eng.obs.write_metrics_jsonl(metrics_path)
    counts = validate_chrome_trace(trace_path)
    assert counts["X"] > 0
    info = validate_metrics_jsonl(metrics_path,
                                  require=("kv_pool_bytes", "kv_blocks"))
    assert info["rows"] >= 1
