"""Hypothesis property-based tests for system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dev dependency")
from hypothesis import given, settings, strategies as st

from repro.configs.base import ModelConfig, MoEConfig
from repro.core.routing import prototype_gating, route, topk_gating
from repro.nn import init
from repro.optim.compression import dequantize_int8, quantize_int8

SETTINGS = dict(max_examples=20, deadline=None)


@st.composite
def routing_cases(draw):
    E = draw(st.sampled_from([2, 4, 8]))
    T = draw(st.integers(4, 40))
    k = draw(st.integers(1, min(E, 3)))
    cap = draw(st.integers(1, T))
    seed = draw(st.integers(0, 2**16))
    return E, T, k, cap, seed


@given(routing_cases())
@settings(**SETTINGS)
def test_topk_invariants(case):
    """For any logits: (a) <=1 token per (expert, slot), (b) each token's
    dispatch count <= k, (c) per-expert load <= capacity, (d) combine
    weights in [0,1] and sum <= 1 per token, (e) dispatch == (combine>0)."""
    E, T, k, cap, seed = case
    cfg = MoEConfig(num_experts=E, routing="topk", top_k=k, aux_loss_coef=0.01)
    logits = jax.random.normal(jax.random.PRNGKey(seed), (1, T, E))
    res = topk_gating(logits, cfg, cap)
    d = np.asarray(res.dispatch)
    c = np.asarray(res.combine)
    assert d.shape == (1, T, E, cap)
    assert (d.sum(axis=1) <= 1).all()          # slot occupancy
    assert (d.sum(axis=(2, 3)) <= k).all()     # per-token fanout
    assert (d.sum(axis=(1, 3)) <= cap).all()   # capacity
    assert (c >= 0).all() and (c <= 1 + 1e-6).all()
    assert (c.sum(axis=(2, 3)) <= 1 + 1e-5).all()
    assert ((c > 0) == d).all()


@given(routing_cases(), st.integers(1, 3))
@settings(**SETTINGS)
def test_prototype_invariants(case, Z):
    F, T, _, cap, seed = case
    E = Z * F
    cfg = MoEConfig(num_experts=E, routing="prototype", num_prototypes=Z,
                    aux_loss_coef=0.01)
    logits = jax.random.normal(jax.random.PRNGKey(seed), (1, Z, T, F))
    res = prototype_gating(logits, cfg, cap)
    d = np.asarray(res.dispatch)
    assert d.shape == (1, T, E, cap)
    assert (d.sum(axis=1) <= 1).all()
    # exactly one expert per prototype per token (before capacity), so
    # fanout <= Z and per-prototype fanout <= 1
    per_proto = d.reshape(1, T, Z, F, cap).sum(axis=(3, 4))
    assert (per_proto <= 1).all()
    assert 0.0 <= float(res.metrics["dropped_fraction"]) <= 1.0


@given(st.integers(0, 2**16), st.integers(1, 64))
@settings(**SETTINGS)
def test_int8_quantization_bounded_error(seed, n):
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(seed), (n,)))
    q, s = quantize_int8(jnp.asarray(x))
    err = np.abs(np.asarray(dequantize_int8(q, s)) - x)
    assert err.max() <= float(s) * 0.5 + 1e-7  # half-ulp of the int8 grid


@given(st.integers(0, 2**16))
@settings(max_examples=10, deadline=None)
def test_checkpoint_roundtrip_property(seed):
    import tempfile

    from repro.checkpoint.checkpointer import Checkpointer

    key = jax.random.PRNGKey(seed)
    tree = {
        "a": jax.random.normal(key, (3, 5)),
        "nested": {"b": jnp.arange(7, dtype=jnp.int32),
                   "c": jax.random.normal(key, (2,), jnp.bfloat16)},
    }
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        ck.save(1, tree)
        restored = ck.restore(1, jax.eval_shape(lambda: tree))
        for a, b in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@given(st.integers(0, 1000), st.integers(0, 1000))
@settings(**SETTINGS)
def test_data_pipeline_deterministic_and_seekable(step1, step2):
    from repro.data.pipeline import SyntheticLM

    p = SyntheticLM(vocab_size=101, batch=2, seq_len=16, seed=7)
    b1 = p.batch_at(step1)
    b1_again = p.batch_at(step1)
    np.testing.assert_array_equal(b1["tokens"], b1_again["tokens"])
    if step1 != step2:
        b2 = p.batch_at(step2)
        assert not np.array_equal(b1["tokens"], b2["tokens"])
    # labels are next-token shifted
    assert (b1["labels"][:, :-1] == b1["tokens"][:, 1:]).all()
