"""End-to-end system test: train -> checkpoint -> restart -> serve, on the
paper's own (smoke-scale) M6 architecture with expert prototyping."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import TrainConfig
from repro.configs.registry import get_smoke_config
from repro.data.pipeline import make_pipeline
from repro.models.registry import get_family
from repro.nn import init
from repro.optim import make_optimizer, warmup_constant
from repro.serving.engine import ServingEngine
from repro.train.state import init_train_state
from repro.train.trainer import make_train_step


def test_train_checkpoint_restart_serve():
    cfg = get_smoke_config("m6-base").replace_moe(
        routing="prototype", num_prototypes=2)
    fam = get_family(cfg)
    tc = TrainConfig(optimizer="adamw", learning_rate=3e-3, warmup_steps=5)
    params = init(fam.specs(cfg), jax.random.PRNGKey(0))
    opt = make_optimizer(tc, warmup_constant(tc.learning_rate, tc.warmup_steps))
    state = init_train_state(params, opt, tc.grad_compression)
    step = jax.jit(make_train_step(cfg, tc, opt))
    pipe = make_pipeline(cfg, 8, 36, seed=0)

    losses = []
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        for i in range(14):
            batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
            if i == 9:
                ck.save_async(i + 1, state)
        ck.wait()

        # simulated failure: restore from step 10, replay the same data
        restored = ck.restore(10, jax.eval_shape(lambda: state))
        for i in range(10, 14):
            batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
            restored, m2 = step(restored, batch)
        # exact resume: same params as the uninterrupted run
        diffs = jax.tree_util.tree_map(
            lambda a, b: float(jnp.abs(a - b).max()), state.params, restored.params)
        assert max(jax.tree_util.tree_leaves(diffs)) < 1e-6

    assert losses[-1] < losses[0]  # the model actually learns

    # serve from the trained params
    engine = ServingEngine(cfg, state.params, max_len=64)
    prompts = jnp.asarray(pipe.batch_at(99)["tokens"][:2, :8])
    toks, stats = engine.generate(prompts, num_tokens=8)
    assert toks.shape == (2, 8)
    assert stats["decode_tokens_per_s"] > 0
