"""Continuous-batching serving subsystem: scheduler + paged-cache
invariants, paged vs dense attention equivalence, and greedy parity
between the continuous engine and the static ServingEngine."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig, ServeConfig
from repro.models.registry import get_family
from repro.nn import init
from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import ServingEngine
from repro.serving.kv_cache import BlockAllocator, PagedKVCache
from repro.serving.request import Request, Status
from repro.serving.scheduler import Scheduler
from repro.serving.trace import run_trace_static, synthetic_trace


def tiny_cfg(**kw) -> ModelConfig:
    base = dict(name="t", family="decoder_lm", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
                max_seq_len=128, dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


def build(cfg, seed=0):
    fam = get_family(cfg)
    return init(fam.specs(cfg), jax.random.PRNGKey(seed))


# ---------------------------------------------------------------------------
# Paged vs dense decode attention
# ---------------------------------------------------------------------------

def _pack_pool(k, v, bs, rng):
    """Scatter a dense (B, T, Hkv, D) cache into a shuffled head-major
    block pool + per-row block tables (blocks deliberately
    non-contiguous: paging must not care)."""
    B, T, Hkv, D = k.shape
    MB = T // bs
    P = B * MB + 1
    perm = rng.permutation(B * MB)
    k_pool = np.zeros((P, Hkv, bs, D), np.float32)
    v_pool = np.zeros((P, Hkv, bs, D), np.float32)
    tables = np.zeros((B, MB), np.int32)
    for b in range(B):
        for m in range(MB):
            blk = int(perm[b * MB + m])
            k_pool[blk] = k[b, m * bs:(m + 1) * bs].transpose(1, 0, 2)
            v_pool[blk] = v[b, m * bs:(m + 1) * bs].transpose(1, 0, 2)
            tables[b, m] = blk
    return k_pool, v_pool, tables


def test_paged_decode_attention_matches_dense():
    from repro.kernels.decode_attention import (
        decode_attention_ref,
        paged_decode_attention,
    )

    rng = np.random.default_rng(0)
    B, T, Hq, Hkv, D, bs = 5, 48, 8, 4, 16, 8
    k = rng.standard_normal((B, T, Hkv, D)).astype(np.float32)
    v = rng.standard_normal((B, T, Hkv, D)).astype(np.float32)
    q = rng.standard_normal((B, Hq, D)).astype(np.float32)
    lengths = np.array([1, 7, 48, 23, 0], np.int32)  # ragged per-slot lengths
    k_pool, v_pool, tables = _pack_pool(k, v, bs, rng)

    dense = decode_attention_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                                 jnp.asarray(lengths))
    paged = paged_decode_attention(jnp.asarray(q), jnp.asarray(k_pool),
                                   jnp.asarray(v_pool), jnp.asarray(tables),
                                   jnp.asarray(lengths))
    active = lengths > 0
    np.testing.assert_allclose(np.asarray(paged)[active],
                               np.asarray(dense)[active], atol=1e-5)
    assert (np.asarray(paged)[~active] == 0).all()  # masked rows: exact 0


def test_paged_kernel_interpret_matches_ref():
    """The Pallas paged kernel (scalar-prefetched block table) in
    interpret mode against the gather reference."""
    from repro.kernels.decode_attention.kernel import paged_decode_attention_kernel
    from repro.kernels.decode_attention.ref import paged_decode_attention_ref

    rng = np.random.default_rng(1)
    N, Hkv, G, D, bs, P, MB = 6, 2, 3, 16, 8, 10, 4
    q = rng.standard_normal((N, Hkv * G, D)).astype(np.float32)
    k_pool = rng.standard_normal((P, Hkv, bs, D)).astype(np.float32)
    v_pool = rng.standard_normal((P, Hkv, bs, D)).astype(np.float32)
    tables = rng.integers(0, P, size=(N, MB)).astype(np.int32)
    lengths = np.array([0, 1, 9, 17, 32, 25], np.int32)

    out = paged_decode_attention_kernel(
        jnp.asarray(q).reshape(N, Hkv, G, D), jnp.asarray(k_pool),
        jnp.asarray(v_pool), jnp.asarray(tables), jnp.asarray(lengths),
        interpret=True).reshape(N, Hkv * G, D)
    ref = paged_decode_attention_ref(jnp.asarray(q), jnp.asarray(k_pool),
                                     jnp.asarray(v_pool), jnp.asarray(tables),
                                     jnp.asarray(lengths))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


# ---------------------------------------------------------------------------
# Allocator / scheduler invariants
# ---------------------------------------------------------------------------

def test_block_allocator_conservation_and_double_free():
    a = BlockAllocator(8)
    xs = a.alloc(3)
    ys = a.alloc(5)
    assert a.free_count == 0 and not a.can_alloc(1)
    with pytest.raises(RuntimeError):
        a.alloc(1)
    a.free(xs)
    a.check_conservation()
    with pytest.raises(RuntimeError):
        a.free(xs)  # double-free detected
    a.free(ys)
    a.check_conservation()
    assert a.free_count == 8
    # reuse: freed ids come back (defrag-free — any id serves any slot)
    assert sorted(a.alloc(8)) == list(range(8))


def test_scheduler_fcfs_slots_and_block_gating():
    cfg = tiny_cfg()
    # 4 blocks of 8 => only one 17..32-token request fits at a time
    serve = ServeConfig(max_slots=4, kv_block_size=8, max_len=32, num_blocks=4)
    cache = PagedKVCache(cfg, serve)
    sched = Scheduler(serve.max_slots, serve.max_len, cache)
    for uid in range(3):
        sched.add(Request(uid=uid, prompt=np.arange(20), max_new_tokens=10))
    admitted = sched.admit(0.0)
    assert [st.request.uid for st in admitted] == [0]  # blocks gate FCFS
    assert sched.running and len(sched.waiting) == 2
    sched.check_conservation()
    st0 = admitted[0]
    assert sched.admit(0.0) == []      # head blocked, nothing admitted behind it
    sched.finish(st0, 1.0)
    sched.check_conservation()
    nxt = sched.admit(1.0)
    assert [st.request.uid for st in nxt] == [1]
    assert nxt[0].slot == st0.slot     # freed slot and blocks reused
    # arrival times respected
    sched.finish(nxt[0], 2.0)
    sched.waiting[0].request.arrival_ms = 99.0
    assert sched.admit(3.0) == []
    assert [st.request.uid for st in sched.admit(99.5)] == [2]


def test_scheduler_rejects_oversized_request():
    sched = Scheduler(2, max_len=16, kv_cache=None)
    with pytest.raises(ValueError):
        sched.add(Request(uid=0, prompt=np.arange(10), max_new_tokens=10))
    # a request that could never fit the block pool must be rejected at
    # add(): FCFS admission would otherwise spin on it for ever
    cache = PagedKVCache(tiny_cfg(), ServeConfig(max_slots=2, kv_block_size=8,
                                                 max_len=32, num_blocks=2))
    sched2 = Scheduler(2, max_len=32, kv_cache=cache)
    with pytest.raises(ValueError):
        sched2.add(Request(uid=1, prompt=np.arange(20), max_new_tokens=10))


def test_engine_run_conserves_slots_and_blocks():
    cfg = tiny_cfg(num_layers=1)
    params = build(cfg)
    serve = ServeConfig(max_slots=2, kv_block_size=8, prefill_chunk=8, max_len=48)
    eng = ContinuousEngine(cfg, params, serve)
    reqs = synthetic_trace(6, cfg.vocab_size, seed=3, qps=1e6,
                           prompt_lens=(3, 12), gen_lens=(2, 5, 9))
    out, stats = eng.run(reqs)
    assert sorted(out) == list(range(6))
    assert all(len(out[r.uid]) == r.max_new_tokens for r in reqs)
    # run() asserts conservation; re-check the end state explicitly
    eng.scheduler.check_conservation()
    assert not eng.scheduler.running and not eng.scheduler.waiting
    assert eng.cache.allocator.free_count == serve.resolved_num_blocks


def test_engine_eos_eviction():
    cfg = tiny_cfg(num_layers=1)
    params = build(cfg)
    eng = ContinuousEngine(cfg, params,
                           ServeConfig(max_slots=1, kv_block_size=8,
                                       prefill_chunk=8, max_len=64))
    # greedy decode, then replay with eos set to the 3rd generated token
    r = Request(uid=0, prompt=np.arange(5), max_new_tokens=16)
    out, _ = eng.run([r])
    eos = out[0][2]
    eng2 = ContinuousEngine(cfg, params,
                            ServeConfig(max_slots=1, kv_block_size=8,
                                        prefill_chunk=8, max_len=64))
    out2, _ = eng2.run([Request(uid=0, prompt=np.arange(5), max_new_tokens=16,
                                eos_id=int(eos))])
    # greedy replay stops at (and includes) the first occurrence of EOS
    assert out2[0] == out[0][:out[0].index(eos) + 1]
    eng2.scheduler.check_conservation()


# ---------------------------------------------------------------------------
# Greedy parity: continuous vs static engine
# ---------------------------------------------------------------------------

def _parity(cfg, B, S, gen, serve, seed=0):
    params = build(cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    toks_s, _ = ServingEngine(cfg, params, max_len=S + gen + 1).generate(prompts, gen)
    eng = ContinuousEngine(cfg, params, serve)
    toks_c, _ = eng.generate(prompts, gen)
    np.testing.assert_array_equal(np.asarray(toks_s), np.asarray(toks_c))
    return eng


def test_parity_single_request_dense():
    _parity(tiny_cfg(), B=1, S=11, gen=9,
            serve=ServeConfig(max_slots=2, kv_block_size=8, prefill_chunk=4,
                              max_len=64))


def test_parity_equal_length_batch_dense():
    # prompt spans multiple chunks and blocks; batch > 1
    eng = _parity(tiny_cfg(), B=3, S=13, gen=8,
                  serve=ServeConfig(max_slots=4, kv_block_size=8,
                                    prefill_chunk=5, max_len=64))
    # static shapes: at most 2 compiled step variants (decode-only, mixed)
    assert eng.steps > 0


def test_parity_slot_reuse_queueing():
    # more requests than slots: later requests wait, reuse freed slots/blocks
    _parity(tiny_cfg(num_layers=1), B=4, S=9, gen=6,
            serve=ServeConfig(max_slots=2, kv_block_size=8, prefill_chunk=4,
                              max_len=32))


def test_parity_moe_dropless_hash():
    """Content/identity routing under slot reuse: hash router reads token
    ids through MoEContext; dropless dispatch so masked filler rows
    cannot perturb real tokens through capacity contention."""
    cfg = tiny_cfg(d_ff=96,
                   moe=MoEConfig(num_experts=4, routing="hash", top_k=2,
                                 impl="dropless", capacity_factor=None,
                                 group_size=64))
    _parity(cfg, B=2, S=9, gen=7,
            serve=ServeConfig(max_slots=2, kv_block_size=8, prefill_chunk=4,
                              max_len=64))


def test_parity_moe_dropless_topk():
    cfg = tiny_cfg(d_ff=96,
                   moe=MoEConfig(num_experts=4, routing="topk", top_k=2,
                                 impl="dropless", capacity_factor=None,
                                 group_size=64))
    _parity(cfg, B=2, S=8, gen=6,
            serve=ServeConfig(max_slots=2, kv_block_size=8, prefill_chunk=8,
                              max_len=32))


def test_parity_xlstm_recurrent_slots():
    cfg = ModelConfig(name="x", family="xlstm", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=128,
                      dtype="float32", xlstm_slstm_period=2)
    # 3 requests on 2 slots: forces per-slot state reset on reuse
    _parity(cfg, B=3, S=6, gen=5,
            serve=ServeConfig(max_slots=2, kv_block_size=8, prefill_chunk=4,
                              max_len=32))


def test_unsupported_families_raise():
    cfg = ModelConfig(name="z", family="zamba", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=128,
                      ssm_state=16, ssm_heads=4, dtype="float32")
    with pytest.raises(NotImplementedError):
        ContinuousEngine(cfg, {}, ServeConfig())


# ---------------------------------------------------------------------------
# Static engine edge case + trace runner + params-only restore
# ---------------------------------------------------------------------------

def test_static_engine_num_tokens_1():
    cfg = tiny_cfg(num_layers=1)
    params = build(cfg)
    eng = ServingEngine(cfg, params, max_len=16)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 4), 0, cfg.vocab_size)
    toks, stats = eng.generate(prompts, num_tokens=1)
    assert toks.shape == (2, 1)
    assert stats["decode_tokens_per_s"] == 0.0  # no decode steps happened


def test_run_trace_static_latencies():
    cfg = tiny_cfg(num_layers=1)
    params = build(cfg)
    eng = ServingEngine(cfg, params, max_len=48)
    reqs = synthetic_trace(4, cfg.vocab_size, seed=0, qps=1e6,
                           prompt_lens=(4, 8), gen_lens=(3, 6))
    out, stats = run_trace_static(eng, reqs, batch=2)
    assert sorted(out) == list(range(4))
    assert all(len(out[r.uid]) == r.max_new_tokens for r in reqs)
    assert stats["p95_ms"] >= stats["p50_ms"] >= 0.0
    assert stats["generated_tokens"] == sum(r.max_new_tokens for r in reqs)


def test_checkpointer_params_only_restore(tmp_path):
    from repro.checkpoint.checkpointer import Checkpointer
    from repro.configs.base import TrainConfig
    from repro.nn import abstract
    from repro.optim import make_optimizer, warmup_constant
    from repro.train.state import init_train_state

    cfg = tiny_cfg(num_layers=1)
    fam = get_family(cfg)
    params = build(cfg, seed=7)
    tc = TrainConfig()
    opt = make_optimizer(tc, warmup_constant(tc.learning_rate))
    state = init_train_state(params, opt, tc.grad_compression)
    ck = Checkpointer(str(tmp_path))
    ck.save(3, state)

    restored, step = ck.restore_params_latest(abstract(fam.specs(cfg)))
    assert step == 3
    diffs = jax.tree_util.tree_map(lambda a, b: float(jnp.abs(a - b).max()),
                                   params, restored)
    assert max(jax.tree_util.tree_leaves(diffs)) == 0.0

    # bare-params checkpoints restore through the same entry point
    ck2 = Checkpointer(str(tmp_path / "bare"))
    ck2.save(1, params)
    restored2, _ = ck2.restore_params_latest(abstract(fam.specs(cfg)))
    diffs2 = jax.tree_util.tree_map(lambda a, b: float(jnp.abs(a - b).max()),
                                    params, restored2)
    assert max(jax.tree_util.tree_leaves(diffs2)) == 0.0
