"""Deterministic goldens for block-level prefix caching
(repro.serving.prefix_cache) plus the operation-sequence checker the
hypothesis harness in test_kv_properties.py randomises.

Covers, bottom-up:

* chain_hash — determinism, token- and parent-sensitivity (absolute
  position is part of a block's identity by construction);
* RefcountedBlockAllocator — bind/release refcounting, the cached-free
  list's LRU eviction order, touch refresh, double-release detection;
* PrefixIndex — bijection, first-writer-wins publication;
* PrefixCachingKVCache — warm admission binds published blocks with the
  right cached token count, the fully-cached-prompt cap (at least one
  prompt row must run), copy-on-write detach keeping the original for
  its other binders, eviction under pool pressure;
* engine level — warm-vs-cold token identity (dense and dropless-hash
  MoE, plus composed with speculative ngram decoding), and capacity
  multiplication on a block-constrained pool;
* the synthetic_multitenant trace family.
"""
import numpy as np
import pytest

import jax

from repro.configs.base import ModelConfig, ServeConfig, SpecConfig
from repro.serving.prefix_cache import (
    ROOT_HASH,
    PrefixCachingKVCache,
    PrefixIndex,
    RefcountedBlockAllocator,
    chain_hash,
)
from repro.serving.trace import synthetic_multitenant


def _cfg():
    return ModelConfig(name="t", family="decoder_lm", num_layers=1,
                       d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
                       vocab_size=64, dtype="float32")


def _cache(max_slots=4, bs=4, num_blocks=16, max_len=64):
    serve = ServeConfig(max_slots=max_slots, kv_block_size=bs,
                        max_len=max_len, num_blocks=num_blocks,
                        prefix_cache=True)
    return PrefixCachingKVCache(_cfg(), serve)


# ---------------------------------------------------------------------------
# chain_hash
# ---------------------------------------------------------------------------

def test_chain_hash_deterministic_and_sensitive():
    toks = np.arange(8, dtype=np.int32)
    h = chain_hash(ROOT_HASH, toks)
    assert h == chain_hash(ROOT_HASH, toks.copy())
    assert len(h) == 16
    assert h != chain_hash(ROOT_HASH, toks + 1)          # token-sensitive
    assert h != chain_hash(h, toks)                      # parent-sensitive
    # same tokens in a different block position (different parent) are a
    # different identity: positions are structural, not stored
    h2 = chain_hash(chain_hash(ROOT_HASH, toks), toks)
    assert h2 != h


# ---------------------------------------------------------------------------
# RefcountedBlockAllocator
# ---------------------------------------------------------------------------

def test_allocator_bind_release_refcounts():
    a = RefcountedBlockAllocator(4)
    (b,) = a.alloc(1, owner=0)
    assert a.refcount(b) == 1 and a.owner(b) == 0
    a.bind(b)                                            # second table binding
    assert a.refcount(b) == 2 and a.owner(b) == 0
    a.release(b, owner_release=True, published=False)
    assert a.refcount(b) == 1 and a.owner(b) is None     # now purely shared
    assert a.live_shared == 1 and a.owned_count == 0
    a.release(b, owner_release=False, published=False)
    assert a.refcount(b) == 0 and a.free_count == 4
    with pytest.raises(RuntimeError):
        a.release(b, owner_release=False, published=False)
    a.check_conservation()


def test_allocator_lru_eviction_order():
    evicted = []
    a = RefcountedBlockAllocator(3, on_evict=evicted.append)
    blocks = a.alloc(3, owner=0)
    for b in blocks:                     # all published, refcount -> 0
        a.release(b, owner_release=True, published=True)
    assert a.cached_count == 3 and a.free_count == 0
    a.touch(blocks[0])                   # refresh: blocks[0] newest now
    got = a.alloc(2, owner=1)
    assert evicted == [blocks[1], blocks[2]]             # oldest first
    assert set(got) == {blocks[1], blocks[2]}
    assert a.evicted_blocks == 2
    # the untouched survivor is still cached and can come back to life
    a.bind(blocks[0])
    assert a.refcount(blocks[0]) == 1 and a.cached_count == 0
    a.check_conservation()


def test_index_bijection_first_writer_wins():
    idx = PrefixIndex()
    h1 = chain_hash(ROOT_HASH, np.arange(4, dtype=np.int32))
    assert idx.put(h1, 7) is True
    assert idx.put(h1, 9) is False       # hash taken: later writer loses
    assert idx.get(h1) == 7 and idx.published(7) and not idx.published(9)
    idx.check_bijection()
    idx.drop_block(7)
    assert idx.get(h1) is None and len(idx) == 0


# ---------------------------------------------------------------------------
# PrefixCachingKVCache goldens
# ---------------------------------------------------------------------------

def test_warm_admission_binds_published_blocks():
    cache = _cache(bs=4, num_blocks=16)
    prompt = np.arange(10, dtype=np.int32)
    assert cache.allocate_slot(0, 14, prompt=prompt) == 0    # cold
    cache.ensure_capacity(0, 10)
    cache.commit(0, prompt)
    blocks_before = list(cache._slot_blocks[0][:2])
    cache.free_slot(0)
    assert cache.allocator.cached_count == 2                 # full blocks only
    ct = cache.allocate_slot(1, 14, prompt=prompt)
    assert ct == 8                                           # 2 of 2.5 blocks
    assert cache._slot_blocks[1][:2] == blocks_before        # same physical ids
    assert cache._slot_bound[1] == 2
    cache.check_conservation()
    # bound region is read-only, first uncached position is writable
    with pytest.raises(RuntimeError):
        cache.write_coords(1, 7)
    cache.ensure_capacity(1, 9)
    cache.write_coords(1, 8)


def test_fully_cached_prompt_keeps_one_row():
    """A prompt of exactly N full blocks matches at most N-1: the engine
    must run at least one prompt row to sample the first token."""
    cache = _cache(bs=4, num_blocks=16)
    prompt = np.arange(12, dtype=np.int32)                   # 3 full blocks
    cache.allocate_slot(0, 16, prompt=prompt)
    cache.ensure_capacity(0, 12)
    cache.commit(0, prompt)
    cache.free_slot(0)
    assert cache.allocate_slot(1, 16, prompt=prompt) == 8    # (3-1) * bs
    cache.check_conservation()


def test_cow_detach_keeps_original_for_binders():
    """Slot B binds blocks slot A published; A truncates into the shared
    region and must detach onto a fresh copy — B's table, the index
    binding, and the block contents stay untouched."""
    cache = _cache(bs=4, num_blocks=16)
    prompt = np.arange(9, dtype=np.int32)
    cache.allocate_slot(0, 12, prompt=prompt)
    cache.ensure_capacity(0, 9)
    cache.commit(0, prompt)                                  # publishes 2 blocks
    ct = cache.allocate_slot(1, 12, prompt=prompt)           # live binding
    assert ct == 8
    shared = list(cache._slot_blocks[1][:2])
    assert cache._slot_blocks[0][:2] == shared
    cache.truncate_slot(0, 6)                # mid-block 1: shared -> COW
    assert cache.stats["cow_detaches"] == 1
    assert cache._slot_blocks[0][1] != shared[1]             # A detached
    assert cache._slot_blocks[1][:2] == shared               # B untouched
    assert cache.index.published(shared[1])                  # still matchable
    assert cache.allocator.refcount(shared[1]) == 1          # B only
    # A's copy is private and writable at the divergence point
    blk, _ = cache.write_coords(0, 6)
    assert blk == cache._slot_blocks[0][1]
    cache.check_conservation()


def test_eviction_under_pressure_unpublishes():
    cache = _cache(bs=4, num_blocks=4, max_len=16)
    prompt = np.arange(8, dtype=np.int32)
    cache.allocate_slot(0, 9, prompt=prompt)
    cache.ensure_capacity(0, 8)
    cache.commit(0, prompt)
    cache.free_slot(0)
    assert cache.allocator.cached_count == 2
    # an unrelated request needs the whole pool: cached blocks evict
    other = 50 + np.arange(13, dtype=np.int32)
    assert cache.allocate_slot(1, 16, prompt=other) == 0
    cache.ensure_capacity(1, 16)
    assert cache.stats["evicted_blocks"] == 2
    assert len(cache.index) == 0
    cache.check_conservation()
    # the old prompt is cold again
    cache.free_slot(1)
    assert cache.allocate_slot(2, 9, prompt=prompt) == 0


# ---------------------------------------------------------------------------
# Operation-sequence checker (randomised by test_kv_properties.py)
# ---------------------------------------------------------------------------

def check_prefix_sequence(max_slots, bs, num_blocks, ops, *,
                          cache_cls=PrefixCachingKVCache, kv_quant="none"):
    """ops: (kind, slot, amount); kind 0=admit-with-prompt,
    1=grow+commit, 2=truncate (then diverge the unwritten tail),
    3=free_slot.  Prompts come from three tenant templates sharing a
    two-block head, so runs hit every sharing shape: live binding, warm
    rebinding after free, divergence at and between block boundaries,
    truncation into the shared region (COW), and LRU eviction under
    pool pressure.

    The host model tracks the token contents of every *published* block
    and asserts the two safety properties sharing must never break: a
    matched prefix always holds exactly the requesting prompt's tokens,
    and a write coordinate never lands in a bound block, a refcount>1
    block, or a published block.  ``cache_cls``/``kv_quant`` run the
    same sequence over a quantized variant — its extended
    ``check_conservation`` asserts the scale-pool/block-table bijection
    after every op."""
    serve = ServeConfig(max_slots=max_slots, kv_block_size=bs,
                        max_len=max(num_blocks * bs, 4),
                        num_blocks=num_blocks, prefix_cache=True,
                        kv_quant=kv_quant)
    cache = cache_cls(_cfg(), serve)
    L = serve.max_len
    common = (np.arange(2 * bs, dtype=np.int64) * 7 % 61).astype(np.int32)
    templates = [
        np.concatenate([common, ((np.arange(L, dtype=np.int64) * 13 + 100 * t)
                                 % 61).astype(np.int32)])[:L]
        for t in range(3)]

    model = {}     # slot -> dict(total, cur, stream, salt)
    pub = {}       # published block -> np.ndarray of its bs token contents

    def sweep():
        for b in list(pub):
            if not cache.index.published(b):
                del pub[b]                      # evicted or diverged

    for kind, slot, amount in ops:
        slot %= max_slots
        if kind == 0 and slot not in model:
            plen = 1 + amount % (L // 2)
            total = min(plen + 1 + amount % 16, L)
            prompt = templates[amount % 3][:plen]
            if cache.can_allocate_slot(total, prompt=prompt):
                ct = cache.allocate_slot(slot, total, prompt=prompt)
                assert ct % bs == 0 and ct <= plen - 1
                held = cache._slot_blocks[slot]
                for k in range(cache._slot_bound[slot]):
                    # match correctness: bound blocks hold exactly the
                    # prompt's tokens (never a colliding other prefix)
                    assert np.array_equal(pub[held[k]],
                                          prompt[k * bs:(k + 1) * bs])
                if ct > 0:
                    with pytest.raises(RuntimeError):
                        cache.write_coords(slot, ct - 1)   # bound = read-only
                tail = ((np.arange(total - plen, dtype=np.int64) * 29 + slot)
                        % 61).astype(np.int32)
                model[slot] = dict(total=total, cur=ct,
                                   stream=np.concatenate([prompt, tail]),
                                   salt=0)
            else:
                with pytest.raises(RuntimeError):
                    cache.allocate_slot(slot, total, prompt=prompt)
        elif kind == 1 and slot in model:
            m = model[slot]
            length = min(m["cur"] + 1 + amount % (2 * bs), m["total"])
            bound = cache._slot_bound[slot]
            if cache.blocks_needed(length) - bound > cache._slot_reserved[slot]:
                # regrowth past truncate-released shared blocks exceeds
                # the exclusive reservation: must refuse, not starve
                with pytest.raises(RuntimeError):
                    cache.ensure_capacity(slot, length)
            else:
                cache.ensure_capacity(slot, length)
                for pos in range(m["cur"], length):
                    blk, _ = cache.write_coords(slot, pos)
                    assert cache.allocator.refcount(blk) == 1
                    assert not cache.index.published(blk)
                m["cur"] = length
                before = cache.committed_blocks(slot)
                cache.commit(slot, m["stream"][:length])
                chain = cache._slot_chain[slot]
                held = cache._slot_blocks[slot]
                for k in range(before, len(chain)):
                    if cache.index.get(chain[k]) == held[k]:
                        pub[held[k]] = m["stream"][k * bs:(k + 1) * bs].copy()
        elif kind == 2 and slot in model:
            m = model[slot]
            new_len = amount % (m["cur"] + 1)
            cache.truncate_slot(slot, new_len)
            m["cur"] = new_len
            # diverge the rewound tail (speculative rollback re-samples),
            # so a later grow+commit publishes different content
            m["salt"] += 1
            tail = ((np.arange(m["total"] - new_len, dtype=np.int64) * 31
                     + 7 * m["salt"] + slot) % 61).astype(np.int32)
            m["stream"] = np.concatenate([m["stream"][:new_len], tail])
        elif kind == 3 and slot in model:
            cache.free_slot(slot)
            del model[slot]
        sweep()
        cache.check_conservation()
    for slot in list(model):
        cache.free_slot(slot)
    sweep()
    cache.check_conservation()
    assert (cache.allocator.free_count + cache.allocator.cached_count
            == num_blocks)


def test_prefix_sequence_fixed_grid():
    # share -> live bind -> truncate into the shared region (COW) ->
    # free both -> re-admit warm -> pressure-evict
    check_prefix_sequence(3, 4, 10, [
        (0, 0, 30),              # tenant 0, cold admit
        (1, 0, 30), (1, 0, 30),  # grow + commit (publishes full blocks)
        (0, 1, 30),              # same tenant: binds live shared blocks
        (2, 0, 5),               # truncate into shared region -> COW edge
        (1, 0, 30),              # regrow within entitlement or refuse
        (3, 0, 0), (3, 1, 0),    # free both; blocks land on cached list
        (0, 2, 30),              # warm re-admit binds cached blocks
        (0, 0, 121), (1, 0, 40),   # different tenant under pressure -> evict
        (3, 0, 0), (3, 2, 0)])
    check_prefix_sequence(2, 2, 6, [
        (0, 0, 9), (1, 0, 11), (2, 0, 0), (1, 0, 9),   # truncate to 0, regrow
        (0, 1, 9), (1, 1, 5), (3, 0, 0), (1, 1, 7), (3, 1, 0)])


# ---------------------------------------------------------------------------
# Engine level: warm vs cold token identity, capacity multiplication
# ---------------------------------------------------------------------------

def tiny_cfg(**kw) -> ModelConfig:
    base = dict(name="t", family="decoder_lm", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
                max_seq_len=128, dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


def _tenant_requests(gen=6):
    """Six requests, two tenants: even uids share one 16-token prompt,
    odd uids share its first 8 tokens then diverge."""
    from repro.serving.request import Request

    rng = np.random.default_rng(0)
    shared = rng.integers(0, 128, 16).astype(np.int32)
    reqs = []
    for uid in range(6):
        if uid % 2 == 0:
            p = shared.copy()
        else:
            p = np.concatenate([shared[:8],
                                rng.integers(0, 128, 8).astype(np.int32)])
        reqs.append(Request(uid=uid, prompt=p, max_new_tokens=gen))
    return reqs


def _serve_trace(cfg, params, *, prefix, num_blocks=48, spec=None, gen=6):
    from repro.serving.continuous import ContinuousEngine

    serve = ServeConfig(max_slots=3, kv_block_size=4, prefill_chunk=4,
                        max_len=64, num_blocks=num_blocks,
                        prefix_cache=prefix, spec=spec)
    eng = ContinuousEngine(cfg, params, serve, check_invariants=True)
    toks, stats = eng.run(_tenant_requests(gen))
    return toks, stats, eng


def _params(cfg, seed=0):
    from repro.models.registry import get_family
    from repro.nn import init

    return init(get_family(cfg).specs(cfg), jax.random.PRNGKey(seed))


def test_warm_vs_cold_identity_dense():
    cfg = tiny_cfg()
    params = _params(cfg)
    cold, _, _ = _serve_trace(cfg, params, prefix=False)
    warm1, s1, eng = _serve_trace(cfg, params, prefix=True)
    warm2, s2, _ = _serve_trace(cfg, params, prefix=True)
    assert cold == warm1 == warm2
    assert s1["cached_tokens"] > 0 and s2["cached_tokens"] > 0
    assert eng.cache.stats["published_blocks"] > 0
    eng.cache.check_conservation()


def test_warm_vs_cold_identity_dropless_hash():
    cfg = tiny_cfg().replace_moe(impl="dropless", num_experts=4,
                                 routing="hash", capacity_factor=None)
    params = _params(cfg)
    cold, _, _ = _serve_trace(cfg, params, prefix=False)
    warm, s, _ = _serve_trace(cfg, params, prefix=True)
    assert cold == warm
    assert s["cached_tokens"] > 0


def test_prefix_composes_with_speculative_ngram():
    cfg = tiny_cfg()
    params = _params(cfg)
    spec = SpecConfig(drafter="ngram", gamma=3)
    plain, _, _ = _serve_trace(cfg, params, prefix=False, gen=8)
    both, s, eng = _serve_trace(cfg, params, prefix=True, spec=spec, gen=8)
    assert plain == both                 # greedy: spec and caching both exact
    assert s["cached_tokens"] > 0
    assert eng.cache.stats["cow_detaches"] == 0   # engine never detaches
    eng.cache.check_conservation()


def test_capacity_multiplication_on_constrained_pool():
    """On a block-starved pool, sharing admits strictly more concurrent
    requests: every even request's worst-case footprint is 6 blocks cold
    but only 2 exclusive once the 16-token tenant prompt is shared."""
    cfg = tiny_cfg()
    params = _params(cfg)
    # 13 blocks * 4 tokens: cold fits two 6-block requests at once
    cold, off, _ = _serve_trace(cfg, params, prefix=False, num_blocks=13)
    warm, on, eng = _serve_trace(cfg, params, prefix=True, num_blocks=13)
    assert cold == warm
    assert on["peak_running"] > off["peak_running"]
    assert on["steps"] < off["steps"]
    eng.cache.check_conservation()


# ---------------------------------------------------------------------------
# synthetic_multitenant trace
# ---------------------------------------------------------------------------

def test_multitenant_trace_shape_and_determinism():
    a = synthetic_multitenant(12, 64, seed=3, num_tenants=3,
                              system_prompt_len=16, suffix_lens=(2, 5),
                              gen_lens=(4, 8))
    b = synthetic_multitenant(12, 64, seed=3, num_tenants=3,
                              system_prompt_len=16, suffix_lens=(2, 5),
                              gen_lens=(4, 8))
    assert len(a) == 12
    for ra, rb in zip(a, b):
        assert np.array_equal(ra.prompt, rb.prompt)        # reproducible
        assert ra.arrival_ms == rb.arrival_ms
    arr = [r.arrival_ms for r in a]
    assert arr == sorted(arr)
    # same tenant -> identical system prompt; different tenant -> not
    assert np.array_equal(a[0].prompt[:16], a[3].prompt[:16])
    assert not np.array_equal(a[0].prompt[:16], a[1].prompt[:16])
    for r in a:
        assert 16 + 2 <= r.prompt_len <= 16 + 5
        assert r.max_new_tokens in (4, 8)
