"""End-to-end training driver: an M6-style multimodal MoE model trained
for a few hundred steps with the full production stack (pjit sharding
rules, ZeRO-1, checkpointing + exact restart, straggler watchdog).

Default is a CPU-friendly ~13M-parameter reduction; --hundred-m scales to
~100M params (same code path, longer wall time).

  PYTHONPATH=src python examples/train_m6_moe.py --steps 300
  PYTHONPATH=src python examples/train_m6_moe.py --hundred-m --steps 200
"""
import argparse
import sys

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--hundred-m", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_m6_ckpt")
    args = ap.parse_args()

    if args.hundred_m:
        # ~100M params: d=512, 4 layers, 16 experts x d_ff 1024
        import repro.configs.m6 as m6
        from repro.configs import registry as reg

        cfg = m6.M6_BASE.replace(
            num_layers=4, d_model=512, num_heads=8, num_kv_heads=8,
            head_dim=64, d_ff=1024, vocab_size=21128, dtype="float32",
            num_image_tokens=8, max_seq_len=256,
        ).replace_moe(num_experts=16, routing="prototype", num_prototypes=2,
                      group_size=512)
        reg._ARCH_MODULES["m6-100m"] = "repro.configs.m6"
        m6.M6_100M = cfg
        reg._M6_ATTR["m6-100m"] = "M6_100M"
        arch = "m6-100m"
        extra = []
    else:
        arch = "m6-base"
        extra = ["--smoke"]

    train_main([
        "--arch", arch, *extra,
        "--steps", str(args.steps),
        "--batch", "16", "--seq", "64",
        "--lr", "3e-3",
        "--routing", "prototype", "--k", "2",
        "--ckpt-dir", args.ckpt_dir,
        "--ckpt-every", "100",
        "--log-every", "20",
    ])


if __name__ == "__main__":
    main()
