"""Quickstart: build an M6-T expert-prototyping MoE LM, train it on the
synthetic clustered-bigram task, and sample from it.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig, TrainConfig
from repro.data.pipeline import make_pipeline
from repro.models.registry import get_family
from repro.nn import count_params, init
from repro.optim import make_optimizer, warmup_constant
from repro.serving.engine import ServingEngine
from repro.train.state import init_train_state
from repro.train.trainer import make_train_step


def main():
    # an MoE LM with the paper's k top-1 expert prototyping: 8 experts in
    # 2 prototypes, each routed top-1 -> quality of top-2, speed of top-1
    cfg = ModelConfig(
        name="quickstart", num_layers=2, d_model=96, num_heads=4,
        num_kv_heads=4, d_ff=192, vocab_size=512, dtype="float32",
        moe=MoEConfig(num_experts=8, routing="prototype", num_prototypes=2,
                      group_size=256, capacity_factor=1.25),
    )
    fam = get_family(cfg)
    print(f"params: {count_params(fam.specs(cfg)):,}")

    tc = TrainConfig(optimizer="adamw", learning_rate=5e-3, warmup_steps=20)
    params = init(fam.specs(cfg), jax.random.PRNGKey(0))
    opt = make_optimizer(tc, warmup_constant(tc.learning_rate, tc.warmup_steps))
    state = init_train_state(params, opt, tc.grad_compression)
    step = jax.jit(make_train_step(cfg, tc, opt))
    pipe = make_pipeline(cfg, batch=16, seq_len=64)

    for i in range(100):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
        state, m = step(state, batch)
        if i % 20 == 0 or i == 99:
            print(f"step {i:3d}  loss {float(m['loss']):.4f}  "
                  f"c_v {float(jnp.mean(m['moe_cv'])):.3f}  "
                  f"dropped {float(jnp.mean(m['moe_dropped_fraction'])):.3f}")

    engine = ServingEngine(cfg, state.params, max_len=96)
    prompts = jnp.asarray(pipe.batch_at(999)["tokens"][:2, :16])
    toks, stats = engine.generate(prompts, num_tokens=16)
    print("generated:", jnp.asarray(toks)[0].tolist())
    print(f"decode: {stats['decode_tokens_per_s']:.1f} tok/s")


if __name__ == "__main__":
    main()
