"""Serving example: static batched generation against an OLMoE-style MoE
model (smoke scale), then the same model behind the continuous-batching
engine on a mixed-length Poisson trace with streaming completions, and
finally the same trace with speculative decoding (prompt-lookup ngram
drafter): greedy, so the outputs are token-identical — only the step
count shrinks.

  PYTHONPATH=src python examples/serve_decode.py          # smoke-scale model
  PYTHONPATH=src python examples/serve_decode.py --fast   # tiny model (CI)
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ServeConfig, SpecConfig
from repro.configs.registry import get_smoke_config
from repro.models.registry import get_family
from repro.nn import init
from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import ServingEngine
from repro.serving.trace import latency_line, synthetic_trace


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="tiny model + short trace (smoke-test mode)")
    args = ap.parse_args(argv)

    if args.fast:
        cfg = ModelConfig(name="tiny", family="decoder_lm", num_layers=1,
                          d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                          vocab_size=256, max_seq_len=128, dtype="float32")
        batches, gen = [1, 2], 8
    else:
        cfg = get_smoke_config("olmoe-1b-7b")
        batches, gen = [1, 4, 8], 32
    fam = get_family(cfg)
    params = init(fam.specs(cfg), jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, max_len=128)

    for batch in batches:
        prompts = jax.random.randint(jax.random.PRNGKey(1), (batch, 32),
                                     0, cfg.vocab_size)
        toks, stats = engine.generate(prompts, num_tokens=gen, temperature=0.8)
        print(f"batch={batch}: prefill {stats['prefill_s']*1e3:.0f}ms, "
              f"decode {stats['decode_tokens_per_s']:.1f} tok/s "
              f"(first tokens: {jnp.asarray(toks)[0, :8].tolist()})")

    # continuous batching: mixed prompt/generation lengths, Poisson
    # arrivals, slots refilled as requests complete
    serve = ServeConfig(max_slots=4, kv_block_size=16, prefill_chunk=16,
                        max_len=128)
    cont = ContinuousEngine(cfg, params, serve, temperature=0.8)
    n_req = 4 if args.fast else 10
    requests = synthetic_trace(n_req, cfg.vocab_size, seed=0, qps=100.0,
                               prompt_lens=(8, 32), gen_lens=(8, 16, 48))

    def stream(st):
        print(f"  req {st.request.uid}: {len(st.generated)} tokens in "
              f"{st.latency_ms():.0f}ms")

    _, stats = cont.run(requests, on_finish=stream)
    print("continuous:", latency_line(stats))

    # speculative decoding: the ngram drafter self-drafts from each
    # slot's own context; greedy verification keeps outputs identical
    # to plain decoding while emitting several tokens per step
    import dataclasses

    sv = dataclasses.replace(serve, spec=SpecConfig(drafter="ngram", gamma=4))
    spec_eng = ContinuousEngine(cfg, params, sv, check_invariants=args.fast)
    base_eng = ContinuousEngine(cfg, params, serve)
    out_spec, spec_stats = spec_eng.run(requests, on_finish=stream)
    out_base, base_stats = base_eng.run(requests)
    assert out_spec == out_base, "greedy speculative output must be identical"
    print("speculative:", latency_line(spec_stats))
    print(f"speculative: acceptance {spec_stats['acceptance_rate']:.2f}, "
          f"{spec_stats['spec_tokens_per_step']:.2f} tokens/verify-step, "
          f"{spec_stats['steps']:.0f} steps vs {base_stats['steps']:.0f} "
          f"non-speculative")


if __name__ == "__main__":
    main()
