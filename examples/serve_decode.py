"""Serving example: batched generation against an OLMoE-style MoE model
(smoke scale) with prefill + KV-cache decode.

  PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_smoke_config
from repro.models.registry import get_family
from repro.nn import init
from repro.serving.engine import ServingEngine


def main():
    cfg = get_smoke_config("olmoe-1b-7b")
    fam = get_family(cfg)
    params = init(fam.specs(cfg), jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, max_len=128)

    for batch in [1, 4, 8]:
        prompts = jax.random.randint(jax.random.PRNGKey(1), (batch, 32),
                                     0, cfg.vocab_size)
        toks, stats = engine.generate(prompts, num_tokens=32, temperature=0.8)
        print(f"batch={batch}: prefill {stats['prefill_s']*1e3:.0f}ms, "
              f"decode {stats['decode_tokens_per_s']:.1f} tok/s "
              f"(first tokens: {jnp.asarray(toks)[0, :8].tolist()})")


if __name__ == "__main__":
    main()
