"""Serving example: static batched generation against an OLMoE-style MoE
model (smoke scale), then the same model behind the continuous-batching
engine on a mixed-length Poisson trace with streaming completions.

  PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ServeConfig
from repro.configs.registry import get_smoke_config
from repro.models.registry import get_family
from repro.nn import init
from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import ServingEngine
from repro.serving.trace import latency_line, synthetic_trace


def main():
    cfg = get_smoke_config("olmoe-1b-7b")
    fam = get_family(cfg)
    params = init(fam.specs(cfg), jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, max_len=128)

    for batch in [1, 4, 8]:
        prompts = jax.random.randint(jax.random.PRNGKey(1), (batch, 32),
                                     0, cfg.vocab_size)
        toks, stats = engine.generate(prompts, num_tokens=32, temperature=0.8)
        print(f"batch={batch}: prefill {stats['prefill_s']*1e3:.0f}ms, "
              f"decode {stats['decode_tokens_per_s']:.1f} tok/s "
              f"(first tokens: {jnp.asarray(toks)[0, :8].tolist()})")

    # continuous batching: mixed prompt/generation lengths, Poisson
    # arrivals, slots refilled as requests complete
    serve = ServeConfig(max_slots=4, kv_block_size=16, prefill_chunk=16,
                        max_len=128)
    cont = ContinuousEngine(cfg, params, serve, temperature=0.8)
    requests = synthetic_trace(10, cfg.vocab_size, seed=0, qps=100.0,
                               prompt_lens=(8, 32), gen_lens=(8, 16, 48))

    def stream(st):
        print(f"  req {st.request.uid}: {len(st.generated)} tokens in "
              f"{st.latency_ms():.0f}ms")

    _, stats = cont.run(requests, on_finish=stream)
    print("continuous:", latency_line(stats))


if __name__ == "__main__":
    main()
