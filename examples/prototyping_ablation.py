"""The paper in one script: train the same MoE model with Top-1, Top-2
and 2 Top-1 (expert prototyping) routing and compare quality + speed —
reproducing the qualitative content of Tables 1-3 / Fig. 3 at CPU scale.
Two beyond-paper baselines from the router registry ride along:
expert-choice (balanced by construction) and stateless hash routing.

  PYTHONPATH=src python examples/prototyping_ablation.py
"""
import time

import jax
import jax.numpy as jnp

from benchmarks.common import bench_config, train_run, variant


def main():
    base = bench_config(layers=2, d_model=96, d_ff=192, experts=8, vocab=512)
    results = {}
    for routing, k, label in [("topk", 1, "Top-1"), ("topk", 2, "Top-2"),
                              ("prototype", 2, "2 Top-1"),
                              ("expert_choice", 2, "EC Top-C"),
                              ("hash", 1, "Hash-1")]:
        cfg = variant(base, routing, k)
        t0 = time.time()
        logs = train_run(cfg, steps=120, batch=24, seq=64, lr=5e-3, log_every=20)
        results[label] = {"final_ce": logs[-1]["ce"],
                          "wall_s": time.time() - t0,
                          "ms_step": 1e3 * sum(r["t"] for r in logs[2:]) / max(len(logs) - 2, 1)}
    print(f"{'routing':10s} {'final CE':>9s} {'ms/step':>9s}")
    for label, r in results.items():
        print(f"{label:10s} {r['final_ce']:9.4f} {r['ms_step']:9.1f}")
    print("\nexpected (paper's claim): Top-2 and 2 Top-1 beat Top-1 on CE;"
          "\n2 Top-1 runs at ~Top-1 speed while Top-2/Top-4 pay the argmax loop."
          "\nbaselines: EC Top-C is balanced by construction (cv=0, no aux loss)"
          "\n  — but its token-axis selection sees future tokens, so its CE is"
          "\n  not decode-reproducible for causal LMs (Zhou et al. 4.1);"
          "\nHash-1 (position hash, no learned router) floors routing's value.")


if __name__ == "__main__":
    main()
